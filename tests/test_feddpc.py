"""FedDPC server-step semantics (paper Algorithm 1, server side)."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import feddpc, projection as proj


def _params(seed=0):
    k = jax.random.PRNGKey(seed)
    return {"w": jax.random.normal(k, (6, 4)), "b": jnp.zeros((4,))}


def _stack(trees):
    return jax.tree.map(lambda *xs: jnp.stack(xs), *trees)


def _flat(t):
    return jnp.concatenate([x.reshape(-1) for x in jax.tree.leaves(t)])


def test_round_one_is_two_sided_fedavg_times_lam_plus_one():
    """Delta_0 -> 0: projection is 0, residual = delta, scale = lam+1."""
    params = _params()
    state = feddpc.init_state(params)
    deltas = _stack([_params(i + 1) for i in range(3)])
    lam = 1.0
    new_p, new_s, _ = feddpc.server_step(state, params, deltas,
                                         eta_g=0.5, lam=lam)
    mean = jax.tree.map(lambda x: x.mean(0), deltas)
    want = jax.tree.map(lambda w, d: w - 0.5 * (lam + 1.0) * d, params, mean)
    np.testing.assert_allclose(_flat(new_p), _flat(want), rtol=1e-5, atol=1e-6)


def test_matches_manual_computation():
    params = _params()
    delta_prev = _params(50)
    state = {"delta_prev": delta_prev}
    deltas_list = [_params(i + 1) for i in range(4)]
    lam = 0.7
    new_p, new_s, diag = feddpc.server_step(state, params,
                                            _stack(deltas_list),
                                            eta_g=1.0, lam=lam)
    # manual per-client
    pf = _flat(delta_prev)
    mods = []
    for d in deltas_list:
        df = _flat(d)
        coef = jnp.vdot(df, pf) / jnp.vdot(pf, pf)
        resid = df - coef * pf
        scale = lam + jnp.linalg.norm(df) / jnp.linalg.norm(resid)
        mods.append(scale * resid)
    want_delta = jnp.stack(mods).mean(0)
    np.testing.assert_allclose(_flat(new_s["delta_prev"]), want_delta,
                               rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(_flat(new_p), _flat(params) - want_delta,
                               rtol=1e-4, atol=1e-5)


def test_global_update_orthogonal_to_previous():
    params = _params()
    state = {"delta_prev": _params(50)}
    deltas = _stack([_params(i + 1) for i in range(4)])
    _, new_s, diag = feddpc.server_step(state, params, deltas, eta_g=1.0)
    cos = float(diag["global_dot_prev"]) / (
        float(proj.tree_norm(new_s["delta_prev"]))
        * float(proj.tree_norm(state["delta_prev"])))
    assert abs(cos) < 1e-3


def test_projection_only_ablation_smaller_update():
    """Without adaptive scaling the aggregated update is the plain mean of
    residuals — strictly smaller norm than the scaled version (scale>=1+lam)."""
    params = _params()
    state = {"delta_prev": _params(50)}
    deltas = _stack([_params(i + 1) for i in range(4)])
    _, s_full, _ = feddpc.server_step(state, params, deltas, eta_g=1.0,
                                      lam=1.0)
    _, s_ablat, _ = feddpc.server_step_projection_only(state, params, deltas,
                                                       eta_g=1.0)
    assert (float(proj.tree_norm(s_full["delta_prev"]))
            > float(proj.tree_norm(s_ablat["delta_prev"])))


def test_jit_and_state_carry():
    params = _params()
    state = feddpc.init_state(params)
    step = jax.jit(lambda s, p, d: feddpc.server_step(s, p, d, 1.0, 1.0))
    for i in range(3):
        deltas = _stack([_params(10 * i + j) for j in range(2)])
        params, state, diag = step(state, params, deltas)
    assert not jnp.isnan(_flat(params)).any()
