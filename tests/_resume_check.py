"""Subprocess body for the checkpoint/resume equivalence test.

Three phases, each a FRESH python process (fresh jit caches, fresh RNGs —
the real crash/requeue scenario), orchestrated by
tests/test_resume.py::test_resume_matches_uninterrupted:

  full    run all ROUNDS rounds uninterrupted; dump finals to <out>.npz
  part    run the first SPLIT rounds, trainer.save(ckpt_dir)
  resume  FederatedTrainer.resume(ckpt_dir, ...), run to the end; dump
          finals to <out>.npz

The comparison (in pytest) asserts params, server state, the sampled
schedule, and per-round losses are EXACTLY equal — bitwise — for a
stateless (feddpc), a per-client-stateful (fedvarp), and an adaptive-LR
(fedexp) server rule, with DEPTH-8 device-staged prefetch (deeper than
the run's remaining rounds, so at save time the staging ring has
sampled every round to the horizon and the checkpoint must roll the
RNG/sampler/schedule back past ALL staged-but-unconsumed rounds —
DESIGN.md §10) and a Markov sampler whose availability chain is itself
checkpointed state.

Beyond the three server rules, two stateful-layer configs ride the same
phases: ``feddpc_guarded`` (update guard ON with a NaN fault plan firing
on BOTH sides of the cut — the guard's rolling norm window must resume
warm or round 4's quarantine decision drifts, DESIGN.md §12) and
``feddpc_fedadam`` (adaptive server optimizer + run-health monitor —
moment state and detector windows must resume bitwise, DESIGN.md §14).
"""
import os
import sys

import numpy as np
import jax
import jax.numpy as jnp

from repro.core.api import AlgoConfig, ExecConfig, FederatedTrainer
from repro.core.faults import FaultPlan
from repro.core.samplers import MarkovSampler

NUM_CLIENTS = 8
K = 3
ROUNDS = 6
SPLIT = 3
# name -> (algo, extra ExecConfig kwargs, FaultPlan kwargs or None)
CONFIGS = {
    "feddpc": ("feddpc", {}, None),
    "fedvarp": ("fedvarp", {}, None),
    "fedexp": ("fedexp", {}, None),
    "feddpc_guarded": ("feddpc",
                       dict(guard=True, guard_min_history=1),
                       dict(nan_rate=0.5, nan_rounds=(1, 4))),
    "feddpc_fedadam": ("feddpc",
                       dict(server_opt="fedadam", health=True,
                            health_window=4, health_min_history=2), None),
}


def loss_fn(p, batch):
    pred = batch["x"] @ p["w"] + p["b"]
    return jnp.mean((pred - batch["y"]) ** 2)


def make_params(seed=0):
    r = np.random.RandomState(seed)
    return {"w": jnp.asarray(r.randn(4, 3), jnp.float32),
            "b": jnp.asarray(r.randn(3), jnp.float32)}


def ragged_batch_fn(c, t):
    r = np.random.RandomState(1000 * c + t)
    return [{"x": r.randn(8, 4).astype(np.float32),
             "y": r.randn(8, 3).astype(np.float32)}
            for _ in range((c % 3) + 1)]


def _cfg(exec_kw):
    return ExecConfig(rounds=ROUNDS, clients_per_round=K, seed=5,
                      eval_every=10 ** 9, prefetch=True, prefetch_depth=8,
                      device_stage=True, **exec_kw)


def _plan(plan_kw):
    return None if plan_kw is None else FaultPlan.seeded(7, **plan_kw)


def build(name):
    algo, exec_kw, plan_kw = CONFIGS[name]
    return FederatedTrainer(
        loss_fn, make_params(), NUM_CLIENTS, ragged_batch_fn,
        _cfg(exec_kw),
        algo=AlgoConfig(name=algo, eta_l=0.05, eta_g=0.1),
        sampler=MarkovSampler(NUM_CLIENTS, K, p_on=0.6, p_off=0.4),
        fault_plan=_plan(plan_kw))


def dump(out_path, trainers):
    arrays = {}
    for name, tr in trainers.items():
        for i, leaf in enumerate(jax.tree.leaves(tr.params)):
            arrays[f"{name}/params/{i}"] = np.asarray(leaf)
        for i, leaf in enumerate(jax.tree.leaves(tr.server_state)):
            arrays[f"{name}/state/{i}"] = np.asarray(leaf)
        arrays[f"{name}/schedule"] = np.stack(tr.schedule[:ROUNDS])
        arrays[f"{name}/losses"] = np.asarray(
            [r.train_loss for r in tr.history], np.float64)
        arrays[f"{name}/quarantined"] = np.asarray(
            [r.quarantined for r in tr.history], np.int64)
        if tr._opt_state is not None:
            for i, leaf in enumerate(jax.tree.leaves(tr._opt_state)):
                arrays[f"{name}/opt/{i}"] = np.asarray(leaf)
        if tr._health is not None:
            arrays[f"{name}/health_loss_window"] = np.asarray(
                tr._health.state_dict()["loss"], np.float64)
    np.savez(out_path, **arrays)


def main(phase, workdir):
    trainers = {}
    for name in CONFIGS:
        algo, exec_kw, plan_kw = CONFIGS[name]
        ckpt_dir = os.path.join(workdir, f"ckpt_{name}")
        if phase == "full":
            with build(name) as tr:
                tr.run()
        elif phase == "part":
            with build(name) as tr:
                for t in range(SPLIT):
                    tr.run_round(t)
                tr.save(ckpt_dir)
        elif phase == "resume":
            with FederatedTrainer.resume(
                    ckpt_dir, loss_fn, make_params(), NUM_CLIENTS,
                    ragged_batch_fn, _cfg(exec_kw),
                    algo=AlgoConfig(name=algo, eta_l=0.05, eta_g=0.1),
                    sampler=MarkovSampler(NUM_CLIENTS, K, p_on=0.6,
                                          p_off=0.4),
                    fault_plan=_plan(plan_kw)) as tr:
                assert tr._start_round == SPLIT, tr._start_round
                tr.run()
        else:
            raise SystemExit(f"unknown phase {phase!r}")
        trainers[name] = tr
    if phase in ("full", "resume"):
        dump(os.path.join(workdir, f"{phase}.npz"), trainers)
    print(f"PHASE {phase} OK")


if __name__ == "__main__":
    main(sys.argv[1], sys.argv[2])
