"""Multi-process runtime contracts (repro.launch.distributed,
DESIGN.md §15): the REPRO_DIST_* environment contract, the
single-machine N-process spawner used by offline CI, the KV-store
barrier / all-max agreement primitives, and failure surfacing. The
actual hierarchical-round equivalence checks live in
tests/test_regime_matrix.py (test_multihost_two_process).
"""
import os
import sys

import pytest

from repro.launch.distributed import (ENV_COORD, ENV_NPROCS, ENV_PID,
                                      DistContext, dist_env, free_port,
                                      spawn_local)

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
WORKER = os.path.join(ROOT, "tests", "_dist_smoke_worker.py")
ENV = {"PYTHONPATH": os.path.join(ROOT, "src")}


def test_dist_env_parses_the_contract():
    env = {ENV_COORD: "127.0.0.1:4321", ENV_NPROCS: "4", ENV_PID: "2"}
    assert dist_env(env) == DistContext(coordinator="127.0.0.1:4321",
                                        num_processes=4, process_id=2)
    # defaults when only the coordinator is set
    assert dist_env({ENV_COORD: "h:1"}) == DistContext(
        coordinator="h:1", num_processes=1, process_id=0)


def test_dist_env_is_none_outside_a_job():
    assert dist_env({}) is None
    assert dist_env({ENV_NPROCS: "2", ENV_PID: "0"}) is None


def test_free_port_binds():
    p = free_port()
    assert 0 < p < 65536


def test_spawn_local_two_process_smoke():
    """2 local processes form one jax.distributed job: topology, the KV
    barrier, and the all-max agreement all work with no network beyond
    127.0.0.1."""
    results = spawn_local([sys.executable, WORKER], 2,
                          devices_per_process=1, env=ENV, timeout_s=300)
    assert len(results) == 2
    for rc, out, _err in results:
        assert rc == 0
        assert "DIST_SMOKE_OK" in out


def test_spawn_local_surfaces_a_failing_child():
    """A child that dies mid-job raises with that child's output tail —
    the offline-CI operator sees WHICH process failed and why."""
    with pytest.raises(RuntimeError, match="child 1 exited 3"):
        spawn_local([sys.executable, WORKER, "--fail"], 2,
                    devices_per_process=1, env=ENV, timeout_s=300)
