"""Expert-parallel shard_map MoE (models/moe_ep.py) == GShard-style
dispatch (models/moe.py), on 1 shard in-process and on a real 2x2 device
mesh in a subprocess (the 4-device XLA override must happen before jax
init, hence the subprocess)."""
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import get_config
from repro.launch.mesh import make_debug_mesh
from repro.models import moe as moe_mod
from repro.models import moe_ep


def test_single_shard_equivalence(rng):
    cfg = get_config("kimi-k2-1t-a32b", smoke=True).with_(capacity_factor=8.0)
    p = moe_mod.init_moe(rng, cfg, jnp.float32)
    x = jax.random.normal(jax.random.fold_in(rng, 1), (2, 8, cfg.d_model))
    o1, a1 = moe_mod.moe_forward(cfg, p, x)
    o2, a2 = moe_ep.moe_forward_ep(cfg, p, x, mesh=make_debug_mesh(1, 1))
    np.testing.assert_allclose(o1, o2, rtol=2e-4, atol=2e-4)
    assert np.isclose(float(a1), float(a2))


def test_single_shard_gradients(rng):
    cfg = get_config("deepseek-v2-236b", smoke=True).with_(
        capacity_factor=8.0, num_shared_experts=0)
    p = moe_mod.init_moe(rng, cfg, jnp.float32)
    x = jax.random.normal(jax.random.fold_in(rng, 2), (1, 8, cfg.d_model))
    mesh = make_debug_mesh(1, 1)

    g1 = jax.grad(lambda pp: moe_mod.moe_forward(cfg, pp, x)[0].sum())(p)
    g2 = jax.grad(lambda pp: moe_ep.moe_forward_ep(
        cfg, pp, x, mesh=mesh)[0].sum())(p)
    for a, b in zip(jax.tree.leaves(g1), jax.tree.leaves(g2)):
        np.testing.assert_allclose(a, b, rtol=5e-4, atol=5e-4)


SUBPROC = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    import jax, jax.numpy as jnp, numpy as np
    from repro.configs.base import get_config
    from repro.models import moe as moe_mod, moe_ep
    cfg = get_config("kimi-k2-1t-a32b", smoke=True).with_(capacity_factor=8.0)
    key = jax.random.PRNGKey(0)
    p = moe_mod.init_moe(key, cfg, jnp.float32)
    x = jax.random.normal(jax.random.fold_in(key, 1), (4, 8, cfg.d_model))
    o1, a1 = moe_mod.moe_forward(cfg, p, x)
    # aux estimator normalizes per token-shard; groups=2 is the matching
    # gshard grouping for a 2-way expert axis
    _, a1g = moe_mod.moe_forward(cfg, p, x, groups=2)
    from repro.launch.mesh import _mesh_kwargs
    mesh = jax.make_mesh((2, 2), ("data", "model"), **_mesh_kwargs(2))
    with mesh:
        o2, a2 = jax.jit(lambda pp, xx: moe_ep.moe_forward_ep(
            cfg, pp, xx, mesh=mesh))(p, x)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2),
                               rtol=3e-4, atol=3e-4)
    assert np.isclose(float(a1g), float(a2), rtol=1e-4), (a1, a1g, a2)
    print("EP-4DEV-OK")
""")


def test_four_device_mesh_equivalence():
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    out = subprocess.run([sys.executable, "-c", SUBPROC], env=env,
                         capture_output=True, text=True, timeout=300,
                         cwd=os.path.dirname(os.path.dirname(__file__)))
    assert "EP-4DEV-OK" in out.stdout, out.stdout + out.stderr
