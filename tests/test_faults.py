"""Chaos-hardening contracts (DESIGN.md §12): seeded fault plans are
order-invariant and replayable, the update guard quarantines EXACTLY the
plan's target set (with the guard-off control going non-finite, so the
counters measure a real defense), round deadlines drop/partial-fold, the
supervised ingest restart preserves the RNG stream bit for bit, and
corruption of the newest checkpoint falls back to the last intact step.

The cross-regime allclose cells for the ``guarded`` regime live in
tests/test_regime_matrix.py; these are the fast single-process contracts.
"""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import checkpoint as ckpt
from repro.core.api import AlgoConfig, ExecConfig, FederatedTrainer
from repro.core.faults import FaultPlan, corrupt_checkpoint
from repro.core.runtime import make_runtime

NUM_CLIENTS = 8
K = 3
ROUNDS = 4


def loss_fn(p, batch):
    pred = batch["x"] @ p["w"] + p["b"]
    return jnp.mean((pred - batch["y"]) ** 2)


def make_params(seed=0):
    r = np.random.RandomState(seed)
    return {"w": jnp.asarray(r.randn(4, 3), jnp.float32),
            "b": jnp.asarray(r.randn(3), jnp.float32)}


def batch_fn(c, t):
    r = np.random.RandomState(1000 * c + t)
    return [{"x": r.randn(8, 4).astype(np.float32),
             "y": r.randn(8, 3).astype(np.float32)}
            for _ in range((c % 2) + 1)]


def make_trainer(plan=None, *, algo="feddpc", rounds=ROUNDS, runtime=None,
                 **exec_kw):
    kw = dict(clients_per_round=K, seed=7, eval_every=10 ** 9)
    kw.update(exec_kw)
    return FederatedTrainer(loss_fn, make_params(), NUM_CLIENTS, batch_fn,
                            ExecConfig(rounds=rounds, **kw),
                            algo=AlgoConfig(name=algo, eta_l=0.05, eta_g=0.1),
                            runtime=runtime, fault_plan=plan)


def assert_trees_equal(a, b):
    for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def params_finite(tr):
    return bool(all(np.all(np.isfinite(np.asarray(leaf)))
                    for leaf in jax.tree.leaves(tr.params)))


# delta faults on rounds >= 1 so the guard's rolling threshold has one
# round of accepted history (round-0 faults would slip past the +inf
# cold-start threshold by design — non-finite still quarantines, norm
# explosions do not)
QPLAN_KW = dict(nan_rate=0.5, nan_rounds=(1,),
                explode_rate=0.5, explode_rounds=(2,))


# ---------------- fault-plan determinism ----------------

def test_plan_codes_are_per_client_and_order_invariant():
    """delta_codes must be a pure function of (seed, round, CLIENT ID):
    permuting the sampled cohort permutes the codes with it, so the plan
    is sampling-order-invariant and the async fold can derive each
    arrival's code individually (prefix stability)."""
    plan = FaultPlan.seeded(11, nan_rate=0.5, explode_rate=0.5)
    sampled = np.array([5, 1, 7, 2])
    codes = plan.delta_codes(3, sampled)
    assert codes.dtype == np.int32 and codes.shape == (4,)
    perm = np.array([2, 0, 3, 1])
    np.testing.assert_array_equal(plan.delta_codes(3, sampled[perm]),
                                  codes[perm])
    # per-arrival derivation == whole-cohort derivation
    singles = [plan.delta_codes(3, np.array([c]))[0] for c in sampled]
    np.testing.assert_array_equal(np.array(singles, np.int32), codes)
    # replay: same query, same answer
    np.testing.assert_array_equal(plan.delta_codes(3, sampled), codes)


def test_plan_config_roundtrip_replays_identically():
    plan = FaultPlan.seeded(7, nan_rate=0.4, nan_rounds=(1, 3),
                            explode_rate=0.3, hang_rate=0.5,
                            ingest_crash_rounds=(2,))
    clone = FaultPlan.from_config(plan.config_dict())
    sampled = np.arange(6)
    for t in range(5):
        np.testing.assert_array_equal(clone.delta_codes(t, sampled),
                                      plan.delta_codes(t, sampled))
        np.testing.assert_array_equal(clone.latency_boost(t, sampled),
                                      plan.latency_boost(t, sampled))
        assert clone.ingest_crash(t) == plan.ingest_crash(t)


# ---------------- guard vs plan: the quarantine oracle ----------------

@pytest.mark.parametrize("algo", ["feddpc", "fedavg", "fedvarp"])
def test_guard_quarantines_exactly_the_plan_targets(algo):
    """Per round, RoundRecord.quarantined == |plan.delta_targets| over
    the realized schedule — no misses, no false positives — and the
    params stay finite through NaN and 1e12x exploded deltas."""
    plan = FaultPlan.seeded(7, **QPLAN_KW)
    with make_trainer(plan, algo=algo, guard=True,
                      guard_min_history=1) as tr:
        recs = tr.run()
        sched = [np.asarray(s) for s in tr.schedule]
        assert params_finite(tr)
    expected = [int(plan.delta_targets(t, sched[t]).sum())
                for t in range(ROUNDS)]
    assert sum(expected) >= 2, expected          # the plan must really fire
    assert [r.quarantined for r in recs] == expected
    assert all(np.isfinite(r.train_loss) for r in recs)


def test_unguarded_nan_control_goes_nonfinite():
    """The control: the same NaN plan with guard=False poisons the
    params — proof the quarantine counters measure a live defense."""
    plan = FaultPlan.seeded(7, **QPLAN_KW)
    with make_trainer(plan, algo="fedavg", guard=False) as tr:
        tr.run()
        assert not params_finite(tr)


def test_guarded_zero_fault_matches_the_unguarded_run():
    """With no faults the guard's threshold stays +inf and every
    multiplier is literally 1.0 — the math is the unguarded round's,
    though the extra guard ops change XLA fusion, so equality is tight
    allclose rather than bitwise (the property the ``guarded``
    regime-matrix regime enrolls on). Zero rows quarantine or clip."""
    outs = {}
    for guard in (False, True):
        with make_trainer(None, guard=guard) as tr:
            recs = tr.run()
            outs[guard] = (tr.params, [r.train_loss for r in recs],
                           sum(r.quarantined + r.clipped for r in recs))
    for a, b in zip(jax.tree.leaves(outs[False][0]),
                    jax.tree.leaves(outs[True][0])):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-6, atol=1e-7)
    np.testing.assert_allclose(outs[False][1], outs[True][1],
                               rtol=1e-6, atol=1e-7)
    assert outs[True][2] == 0


def test_moderate_explosions_clip_instead_of_quarantining():
    """Norms between clip_mult x thresh and quarantine_mult x thresh are
    scaled DOWN to the clip limit, not dropped: the clipped counter
    fires, quarantined stays 0, and the run stays finite."""
    plan = FaultPlan.seeded(3, explode_rate=1.0, explode_rounds=(1,),
                            explode_magnitude=50.0)
    with make_trainer(plan, guard=True, guard_min_history=1,
                      guard_clip_mult=2.0,
                      guard_quarantine_mult=1e8) as tr:
        recs = tr.run()
        sched = [np.asarray(s) for s in tr.schedule]
        assert params_finite(tr)
    expected = int(plan.delta_targets(1, sched[1]).sum())
    assert expected == K                         # rate 1.0: whole cohort
    assert recs[1].clipped == expected
    assert sum(r.quarantined for r in recs) == 0
    assert sum(r.clipped for r in recs) == expected


# ---------------- round deadlines ----------------

def test_sync_deadline_drops_hung_clients():
    """DeterministicRuntime + a rate-1.0 hang on round 1: every sampled
    client's latency blows past round_deadline, the round fires its
    deadline, all K rows fold out (sentinel + mask), and the run stays
    finite with the other rounds untouched."""
    plan = FaultPlan.seeded(5, hang_rate=1.0, hang_rounds=(1,))
    with make_trainer(plan, guard=True, round_deadline=10.0,
                      runtime=make_runtime("deterministic",
                                           NUM_CLIENTS)) as tr:
        recs = tr.run()
        assert params_finite(tr)
    assert [r.deadline_fired for r in recs] == [0, 1, 0, 0]
    assert [r.deadline_dropped for r in recs] == [0, K, 0, 0]
    assert all(np.isfinite(r.train_loss) for r in recs)


def test_async_deadline_folds_partial_buffer():
    """Buffered-async + heavy-tail latencies + a tight deadline: some
    server steps must fold a PARTIAL buffer (deadline_fired, with the
    missing arrivals counted as deadline_dropped), and every fold still
    folds at least one arrival, so the run completes finite."""
    with make_trainer(None, guard=True, async_buffer=True,
                      async_concurrency=4, round_deadline=0.3,
                      runtime=make_runtime("heavytail", NUM_CLIENTS,
                                           shape=1.2, scale=0.5)) as tr:
        recs = tr.run()
        assert params_finite(tr)
    assert sum(r.deadline_fired for r in recs) > 0
    assert sum(r.deadline_dropped for r in recs) > 0
    assert all(np.isfinite(r.train_loss) for r in recs)


# ---------------- uplink accounting (shipped semantics) ----------------

def test_deadline_dropped_clients_still_pay_uplink():
    """Bytes are counted when a delta is SHIPPED, regardless of whether
    the fold uses it: the rate-1.0 hang round deadline-drops all K
    clients, but each of them computed and shipped its (codec-encoded)
    update — so the deadline round's comm_bytes_up equals every other
    round's, on both the fused and the serial path, with the int8 codec
    shrinking (never re-weighting) the wire size."""
    for vec in (True, False):
        byt = {}
        for codec in (None, "int8"):
            plan = FaultPlan.seeded(5, hang_rate=1.0, hang_rounds=(1,))
            kw = {} if codec is None else {"codec": codec}
            with make_trainer(plan, vectorize=vec, guard=True,
                              round_deadline=10.0,
                              runtime=make_runtime("deterministic",
                                                   NUM_CLIENTS),
                              **kw) as tr:
                recs = tr.run()
            assert [r.deadline_dropped for r in recs] == [0, K, 0, 0]
            per_round = [r.comm_bytes_up for r in recs]
            assert per_round[1] == per_round[0] > 0, (vec, codec, per_round)
            assert len(set(per_round)) == 1, (vec, codec, per_round)
            byt[codec] = per_round[0]
        assert byt["int8"] < byt[None]


def test_runtime_dropouts_never_pay_uplink():
    """The other half of the shipped semantics: a runtime DROPOUT never
    produced an update, so it pays nothing — per round, comm_bytes_up
    counts exactly the K - dropped clients that shipped (with a huge
    deadline, deadline_dropped IS the dropout count)."""
    for vec in (True, False):
        with make_trainer(None, vectorize=vec, round_deadline=1e9,
                          runtime=make_runtime("exponential", NUM_CLIENTS,
                                               mean=0.5, dropout=0.5)) as tr:
            recs = tr.run()
            per_client = tr._client_bytes_up
        assert sum(r.deadline_dropped for r in recs) > 0, vec
        for r in recs:
            assert r.comm_bytes_up == per_client * (K - r.deadline_dropped), \
                (vec, r.round, r.comm_bytes_up, r.deadline_dropped)


# ---------------- self-healing ingest ----------------

def test_ingest_crash_restart_preserves_the_run_bitwise():
    """A budgeted producer crash is retried — and because the crash hook
    fires BEFORE the cohort draw (and the draw is cached across retries),
    the recovered run's schedule, params, and losses are bitwise the
    no-fault run's."""
    plan = FaultPlan.seeded(5, ingest_crash_rounds=(1,))
    with make_trainer(plan, ingest_max_restarts=2) as tr:
        recs = tr.run()
        faulted = (tr.params, [r.train_loss for r in recs],
                   [np.asarray(s) for s in tr.schedule])
        # attribution invariant: the restart is charged to the round
        # whose STAGING crashed (round 1) — even though the prefetch ring
        # stages round 1 while round 0's program is still on device
        assert [r.ingest_restarts for r in recs] == [0, 1, 0, 0]
    with make_trainer(None) as tr:
        recs = tr.run()
        clean = (tr.params, [r.train_loss for r in recs],
                 [np.asarray(s) for s in tr.schedule])
        assert sum(r.ingest_restarts for r in recs) == 0
    assert_trees_equal(faulted[0], clean[0])
    np.testing.assert_array_equal(faulted[1], clean[1])
    for a, b in zip(faulted[2], clean[2]):
        np.testing.assert_array_equal(a, b)


def test_ingest_crash_past_budget_raises_with_producer_traceback():
    """ingest_max_restarts=0 keeps the historical fail-fast: the injected
    crash propagates out of run(), and the consumer-side RuntimeError
    carries the producer's own traceback text (the frames inside
    produce_fn would otherwise be lost)."""
    plan = FaultPlan.seeded(5, ingest_crash_rounds=(1,))
    with make_trainer(plan, ingest_max_restarts=0) as tr:
        with pytest.raises(RuntimeError,
                           match="injected ingest producer crash") as ei:
            tr.run()
    assert "producer traceback" in str(ei.value)


# ---------------- self-healing checkpoints ----------------

def _run_and_save_twice(d, plan=None, **exec_kw):
    """Run 4 rounds saving after rounds 1 and 3 (steps 2 and 4); return
    the params snapshot at each saved step."""
    snaps = {}
    with make_trainer(plan, **exec_kw) as tr:
        for t in range(ROUNDS):
            tr.run_round(t)
            if t in (1, 3):
                tr.save(d, keep=5)
                snaps[t + 1] = jax.tree.map(np.asarray, tr.params)
    return snaps


@pytest.mark.parametrize("mode", ["truncate", "bitflip", "drop_digest",
                                  "missing_sidecar"])
def test_corrupt_newest_step_falls_back_to_last_good(mode, tmp_path):
    """Every corruption shape — truncated npz, digest mismatch (bitflip),
    missing manifest, missing digested sidecar — must skip the damaged
    newest step and restore the older intact one BITWISE; an EXPLICITLY
    requested corrupt step must fail loudly, never silently fall back."""
    d = str(tmp_path)
    snaps = _run_and_save_twice(d)
    if mode == "missing_sidecar":
        os.remove(os.path.join(d, "step_00000004", "aux.npz"))
    else:
        corrupt_checkpoint(d, 4, mode)
    with pytest.warns(RuntimeWarning):
        assert ckpt.resolve_step(d) == 2
    with make_trainer(None) as tr:
        with pytest.warns(RuntimeWarning):
            tr.restore(d)
        assert tr.start_round == 2
        assert_trees_equal(tr.params, snaps[2])
    with make_trainer(None) as tr:
        with pytest.raises(ValueError):
            tr.restore(d, step=4)


def test_guarded_faulted_resume_is_bitwise(tmp_path):
    """Save mid-run with the guard active and the fault plan firing;
    a fresh resume (same plan) must reproduce the uninterrupted run's
    params and losses bit for bit — guard window state included."""
    plan = FaultPlan.seeded(7, **QPLAN_KW)
    kw = dict(guard=True, guard_min_history=1)
    with make_trainer(plan, **kw) as tr:
        full_recs = tr.run()
        full = jax.tree.map(np.asarray, tr.params)
    d = str(tmp_path)
    with make_trainer(plan, **kw) as tr:
        for t in range(2):
            tr.run_round(t)
        tr.save(d)
    tr = FederatedTrainer.resume(
        d, loss_fn, make_params(), NUM_CLIENTS, batch_fn,
        ExecConfig(rounds=ROUNDS, clients_per_round=K, seed=7,
                   eval_every=10 ** 9, **kw),
        algo=AlgoConfig(name="feddpc", eta_l=0.05, eta_g=0.1),
        fault_plan=plan)
    with tr:
        res_recs = tr.run()
        assert_trees_equal(tr.params, full)
    np.testing.assert_array_equal(
        [r.train_loss for r in full_recs],
        [r.train_loss for r in res_recs])


def test_async_guarded_midbuffer_resume_is_bitwise(tmp_path):
    """The hardest resume cell: buffered-async with concurrency > 1 (so
    the in-flight heap is non-empty at save time), guard on, faults
    firing — the fresh-resume run must still be bitwise."""
    plan = FaultPlan.seeded(7, **QPLAN_KW)
    kw = dict(guard=True, guard_min_history=1, async_buffer=True,
              async_concurrency=2)
    rt = lambda: make_runtime("exponential", NUM_CLIENTS, mean=0.7)
    with make_trainer(plan, runtime=rt(), **kw) as tr:
        full_recs = tr.run()
        full = jax.tree.map(np.asarray, tr.params)
        assert params_finite(tr)
    d = str(tmp_path)
    with make_trainer(plan, runtime=rt(), **kw) as tr:
        for t in range(2):
            tr.run_round(t)
        tr.save(d)
    tr = FederatedTrainer.resume(
        d, loss_fn, make_params(), NUM_CLIENTS, batch_fn,
        ExecConfig(rounds=ROUNDS, clients_per_round=K, seed=7,
                   eval_every=10 ** 9, **kw),
        algo=AlgoConfig(name="feddpc", eta_l=0.05, eta_g=0.1),
        runtime=rt(), fault_plan=plan)
    with tr:
        res_recs = tr.run()
        assert_trees_equal(tr.params, full)
    np.testing.assert_array_equal(
        [r.train_loss for r in full_recs],
        [r.train_loss for r in res_recs])


# ---------------- process loss / edge drops (DESIGN.md §15) ----------

EK = 4        # clients_per_round for the edge cells: 2 edges x 2 rows


def edge_trainer(plan, **kw):
    kw.setdefault("clients_per_round", EK)
    return make_trainer(plan, edges=2, **kw)


def test_edge_drop_plan_is_deterministic_and_roundtrips():
    """EdgeDrop queries the EDGE index space, replays identically from
    its config dict, and only fires inside its round set."""
    plan = FaultPlan.seeded(11, edge_drop_rate=0.6, edge_drop_rounds=(1, 2))
    assert plan.injects_edges
    again = FaultPlan.from_config(plan.config_dict())
    assert again.injects_edges
    for t in range(ROUNDS):
        np.testing.assert_array_equal(plan.edge_drops(t, 4),
                                      again.edge_drops(t, 4))
        if t not in (1, 2):
            assert not plan.edge_drops(t, 4).any()
    targeted = FaultPlan.seeded(0, edge_drop_edges=(1,),
                                edge_drop_rounds=(2,))
    np.testing.assert_array_equal(targeted.edge_drops(2, 2),
                                  np.array([False, True]))
    assert not targeted.edge_drops(1, 2).any()
    assert not FaultPlan.seeded(11, **QPLAN_KW).injects_edges


def test_edge_drop_folds_surviving_edges_and_loses_the_summary_hop():
    """Losing edge 1 on round 2: the server folds the surviving edge's
    partial (run stays finite), the round records edge_dropped, and the
    comm split shows it — every client still paid the client->edge
    uplink (they DID ship), but only the live edge pays the
    edge->server summary hop."""
    plan = FaultPlan.seeded(0, edge_drop_edges=(1,), edge_drop_rounds=(2,))
    with edge_trainer(plan) as tr:
        recs = tr.run()
        assert params_finite(tr)
    assert [r.edge_dropped for r in recs] == [0, 0, 1, 0]
    for r in recs:
        assert r.comm_bytes_edge_up == r.comm_bytes_up > 0
        assert r.comm_bytes_server_up == \
            (2 - r.edge_dropped) * tr._summary_bytes_up
        assert np.isfinite(r.train_loss)


def test_edge_drop_matches_across_fused_and_serial_paths():
    """The fused (masked two-level fold in one jit) and serial (python
    loop) engines implement the edge loss independently — the same plan
    must produce the same drops and allclose state on both."""
    plan = FaultPlan.seeded(3, edge_drop_rate=0.5)
    with edge_trainer(plan) as a:
        ra = a.run()
    with edge_trainer(plan, vectorize=False) as b:
        rb = b.run()
    assert [r.edge_dropped for r in ra] == [r.edge_dropped for r in rb]
    assert sum(r.edge_dropped for r in ra) > 0        # plan really fired
    assert sum(r.edge_dropped for r in ra) < 2 * ROUNDS   # and some lived
    for x, y in zip(jax.tree.leaves(a.params), jax.tree.leaves(b.params)):
        np.testing.assert_allclose(np.asarray(x), np.asarray(y),
                                   rtol=1e-5, atol=1e-6)
    for x, y in zip(ra, rb):
        assert np.isclose(x.train_loss, y.train_loss,
                          rtol=1e-4, atol=1e-6)


def test_edge_drop_replay_is_bitwise():
    plan = FaultPlan.seeded(5, edge_drop_rate=0.5)
    with edge_trainer(plan) as a:
        a.run()
    with edge_trainer(plan) as b:
        b.run()
    assert_trees_equal(a.params, b.params)
    assert_trees_equal(a.server_state, b.server_state)


def test_all_edges_down_is_a_finite_noop_round():
    """A full partition (every edge lost on round 1) must not poison the
    run: nothing reaches the server, the fold is a no-op, the loss
    reports 0.0 for the dead round, and training continues."""
    plan = FaultPlan.seeded(0, edge_drop_edges=(0, 1),
                            edge_drop_rounds=(1,))
    with edge_trainer(plan) as tr:
        recs = tr.run()
        assert params_finite(tr)
    assert [r.edge_dropped for r in recs] == [0, 2, 0, 0]
    assert recs[1].train_loss == 0.0
    assert recs[1].comm_bytes_server_up == 0
    assert recs[1].comm_bytes_edge_up > 0
