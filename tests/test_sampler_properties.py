"""Property-based tests for the ClientSampler round-order RNG contract
(DESIGN.md §3).

The contract the trainer, the cohort prefetcher, and save()/resume()
all lean on:

  * ``sample(rng, t)`` is a pure function of (rng state, sampler state,
    t) — the schedule depends only on the seed and the ROUND ORDER of
    the draws, never on when they happen. That is what makes a
    prefetched run (which draws rounds ahead of consumption) reproduce
    a blocking one, at ANY staging depth.
  * cohorts are exactly ``cohort_size`` distinct in-range ids (the jit
    shape bucket must not vary).
  * ``state_dict()/load_state_dict()`` + the numpy RNG state capture
    EVERYTHING a stateful sampler evolves, so a checkpoint cut at an
    arbitrary round boundary re-draws the remaining rounds identically
    (the unit contract under FederatedTrainer.save()/resume()).

Runs under hypothesis when installed, else the deterministic fallback
(tests/_hypothesis_compat.py).
"""
import numpy as np
from _hypothesis_compat import given, settings, st

from repro.core.samplers import sampler_matrix

settings.register_profile("ci", max_examples=25, deadline=None)
settings.load_profile("ci")

ROUNDS = 8
KINDS = tuple(sampler_matrix(4, 2))     # auto-enrolls new sampler kinds


def _schedule(kind, n, k, seed, rounds=ROUNDS, start=0, sampler=None,
              rng=None):
    sampler = sampler if sampler is not None else sampler_matrix(n, k)[kind]
    rng = rng if rng is not None else np.random.RandomState(seed)
    return sampler, rng, [np.asarray(sampler.sample(rng, t))
                          for t in range(start, rounds)]


@given(st.sampled_from(KINDS), st.integers(2, 40), st.integers(1, 6),
       st.integers(0, 2 ** 16))
def test_cohorts_are_exact_distinct_in_range(kind, n, k, seed):
    k = min(k, n)
    _, _, sched = _schedule(kind, n, k, seed)
    for t, cohort in enumerate(sched):
        assert cohort.shape == (k,), (kind, t, cohort)
        assert len(np.unique(cohort)) == k, (kind, t, cohort)
        assert cohort.min() >= 0 and cohort.max() < n, (kind, t, cohort)


@given(st.sampled_from(KINDS), st.integers(2, 40), st.integers(1, 6),
       st.integers(0, 2 ** 16), st.integers(1, 6))
def test_schedule_independent_of_staging_depth(kind, n, k, seed, depth):
    """Prefetch-depth independence: a producer that stages ``depth``
    rounds ahead of the consumer draws the EXACT schedule of a blocking
    draw-on-demand loop, because draws happen in round order either way
    and ``sample`` reads nothing but (rng, state, round)."""
    k = min(k, n)
    _, _, on_demand = _schedule(kind, n, k, seed)
    # staged: fill a look-ahead buffer of `depth` rounds, then interleave
    # produce/consume exactly as CohortPrefetcher does
    sampler = sampler_matrix(n, k)[kind]
    rng = np.random.RandomState(seed)
    staged, buf, produced = [], [], 0
    while len(staged) < ROUNDS:
        while produced < ROUNDS and len(buf) < depth:
            buf.append(np.asarray(sampler.sample(rng, produced)))
            produced += 1
        staged.append(buf.pop(0))
    for a, b in zip(on_demand, staged):
        assert (a == b).all(), (kind, depth)


@given(st.sampled_from(KINDS), st.integers(2, 40), st.integers(1, 6),
       st.integers(0, 2 ** 16), st.integers(0, ROUNDS - 1))
def test_state_roundtrips_at_arbitrary_round_boundary(kind, n, k, seed,
                                                      boundary):
    """Cut the run at ANY round boundary, capture (state_dict, rng
    state) — what FederatedTrainer.save() checkpoints — and rebuild a
    fresh sampler from them: the remaining rounds re-draw identically.
    Covers the stateful Markov chain mid-trajectory and the stateless
    samplers (whose state_dict is empty by contract)."""
    k = min(k, n)
    sampler, rng, head = _schedule(kind, n, k, seed, rounds=boundary)
    snap_state = sampler.state_dict()
    snap_rng = rng.get_state()
    # branch A: continue in place
    _, _, tail_a = _schedule(kind, n, k, seed, start=boundary,
                             sampler=sampler, rng=rng)
    # branch B: fresh construction + restore, as resume() does
    sampler_b = sampler_matrix(n, k)[kind]
    assert sampler_b.config_dict() == sampler.config_dict()
    sampler_b.load_state_dict(snap_state)
    rng_b = np.random.RandomState(0)
    rng_b.set_state(snap_rng)
    _, _, tail_b = _schedule(kind, n, k, seed, start=boundary,
                             sampler=sampler_b, rng=rng_b)
    for a, b in zip(tail_a, tail_b):
        assert (a == b).all(), (kind, boundary)


@given(st.integers(2, 40), st.integers(1, 6), st.integers(0, 2 ** 16))
def test_markov_state_dict_json_roundtrip(n, k, seed):
    """The Markov availability vector survives the JSON sidecar channel
    (checkpoint aux.json): dict -> json -> dict -> load_state_dict."""
    import json
    k = min(k, n)
    sampler, rng, _ = _schedule("markov", n, k, seed, rounds=3)
    state = json.loads(json.dumps(sampler.state_dict()))
    sampler_b = sampler_matrix(n, k)["markov"]
    sampler_b.load_state_dict(state)
    assert (sampler_b._avail == sampler._avail).all()
