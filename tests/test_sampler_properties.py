"""Property-based tests for the ClientSampler round-order RNG contract
(DESIGN.md §3).

The contract the trainer, the cohort prefetcher, and save()/resume()
all lean on:

  * ``sample(rng, t)`` is a pure function of (rng state, sampler state,
    t) — the schedule depends only on the seed and the ROUND ORDER of
    the draws, never on when they happen. That is what makes a
    prefetched run (which draws rounds ahead of consumption) reproduce
    a blocking one, at ANY staging depth.
  * cohorts are exactly ``cohort_size`` distinct in-range ids (the jit
    shape bucket must not vary).
  * ``state_dict()/load_state_dict()`` + the numpy RNG state capture
    EVERYTHING a stateful sampler evolves, so a checkpoint cut at an
    arbitrary round boundary re-draws the remaining rounds identically
    (the unit contract under FederatedTrainer.save()/resume()).

Runs under hypothesis when installed, else the deterministic fallback
(tests/_hypothesis_compat.py).
"""
import numpy as np
from _hypothesis_compat import given, settings, st

from repro.core.samplers import sampler_matrix

settings.register_profile("ci", max_examples=25, deadline=None)
settings.load_profile("ci")

ROUNDS = 8
KINDS = tuple(sampler_matrix(4, 2))     # auto-enrolls new sampler kinds


def _schedule(kind, n, k, seed, rounds=ROUNDS, start=0, sampler=None,
              rng=None):
    sampler = sampler if sampler is not None else sampler_matrix(n, k)[kind]
    rng = rng if rng is not None else np.random.RandomState(seed)
    return sampler, rng, [np.asarray(sampler.sample(rng, t))
                          for t in range(start, rounds)]


@given(st.sampled_from(KINDS), st.integers(2, 40), st.integers(1, 6),
       st.integers(0, 2 ** 16))
def test_cohorts_are_exact_distinct_in_range(kind, n, k, seed):
    k = min(k, n)
    _, _, sched = _schedule(kind, n, k, seed)
    for t, cohort in enumerate(sched):
        assert cohort.shape == (k,), (kind, t, cohort)
        assert len(np.unique(cohort)) == k, (kind, t, cohort)
        assert cohort.min() >= 0 and cohort.max() < n, (kind, t, cohort)


@given(st.sampled_from(KINDS), st.integers(2, 40), st.integers(1, 6),
       st.integers(0, 2 ** 16), st.integers(1, 6))
def test_schedule_independent_of_staging_depth(kind, n, k, seed, depth):
    """Prefetch-depth independence: a producer that stages ``depth``
    rounds ahead of the consumer draws the EXACT schedule of a blocking
    draw-on-demand loop, because draws happen in round order either way
    and ``sample`` reads nothing but (rng, state, round)."""
    k = min(k, n)
    _, _, on_demand = _schedule(kind, n, k, seed)
    # staged: fill a look-ahead buffer of `depth` rounds, then interleave
    # produce/consume exactly as CohortPrefetcher does
    sampler = sampler_matrix(n, k)[kind]
    rng = np.random.RandomState(seed)
    staged, buf, produced = [], [], 0
    while len(staged) < ROUNDS:
        while produced < ROUNDS and len(buf) < depth:
            buf.append(np.asarray(sampler.sample(rng, produced)))
            produced += 1
        staged.append(buf.pop(0))
    for a, b in zip(on_demand, staged):
        assert (a == b).all(), (kind, depth)


@given(st.sampled_from(KINDS), st.integers(2, 40), st.integers(1, 6),
       st.integers(0, 2 ** 16), st.integers(0, ROUNDS - 1))
def test_state_roundtrips_at_arbitrary_round_boundary(kind, n, k, seed,
                                                      boundary):
    """Cut the run at ANY round boundary, capture (state_dict, rng
    state) — what FederatedTrainer.save() checkpoints — and rebuild a
    fresh sampler from them: the remaining rounds re-draw identically.
    Covers the stateful Markov chain mid-trajectory and the stateless
    samplers (whose state_dict is empty by contract)."""
    k = min(k, n)
    sampler, rng, head = _schedule(kind, n, k, seed, rounds=boundary)
    snap_state = sampler.state_dict()
    snap_rng = rng.get_state()
    # branch A: continue in place
    _, _, tail_a = _schedule(kind, n, k, seed, start=boundary,
                             sampler=sampler, rng=rng)
    # branch B: fresh construction + restore, as resume() does
    sampler_b = sampler_matrix(n, k)[kind]
    assert sampler_b.config_dict() == sampler.config_dict()
    sampler_b.load_state_dict(snap_state)
    rng_b = np.random.RandomState(0)
    rng_b.set_state(snap_rng)
    _, _, tail_b = _schedule(kind, n, k, seed, start=boundary,
                             sampler=sampler_b, rng=rng_b)
    for a, b in zip(tail_a, tail_b):
        assert (a == b).all(), (kind, boundary)


def test_markov_rng_draw_count_is_pinned_per_branch():
    """The MarkovSampler consumes a FIXED number of draws per branch:
    chain evolution + ONE cohort draw normally, chain evolution + TWO
    (shortfall choice + the de-sorting permutation) when fewer than K
    clients are up. The permutation is part of the contract — without
    it the shortfall branch returned sorted up_ids first, leaking
    availability through cohort position (regression)."""
    from repro.core.samplers import MarkovSampler
    n, k = 6, 3
    # normal branch: plenty of clients up
    s = MarkovSampler(n, k, p_on=1.0, p_off=0.0)
    s.load_state_dict({"avail": [1] * n})
    rng_a, rng_b = np.random.RandomState(5), np.random.RandomState(5)
    cohort = s.sample(rng_a, 1)
    rng_b.rand(n)                          # chain evolution
    rng_b.choice(np.arange(n), size=k, replace=False)
    assert (rng_a.get_state()[1] == rng_b.get_state()[1]).all()
    assert len(cohort) == k
    # shortfall branch: force exactly one client up (p_off=0 keeps the
    # up client up, p_on~0 keeps the rest down, so the post-evolution
    # availability is deterministic)
    s = MarkovSampler(n, k, p_on=1e-9, p_off=0.0)
    s.load_state_dict({"avail": [1] + [0] * (n - 1)})
    rng_a, rng_b = np.random.RandomState(9), np.random.RandomState(9)
    cohort = s.sample(rng_a, 1)
    rng_b.rand(n)                          # chain evolution
    up = np.flatnonzero(np.asarray([1] + [0] * (n - 1), bool))
    down = np.flatnonzero(~np.asarray([1] + [0] * (n - 1), bool))
    drafted = rng_b.choice(down, size=k - len(up), replace=False)
    perm = rng_b.permutation(k)
    assert (rng_a.get_state()[1] == rng_b.get_state()[1]).all()
    # and the cohort is the shuffled concatenation, not sorted-up-first
    want = np.concatenate([up, drafted])[perm]
    assert (np.asarray(cohort) == want).all()
    assert 0 in cohort and len(np.unique(cohort)) == k


@given(st.integers(2, 40), st.integers(1, 6), st.integers(0, 2 ** 16))
def test_markov_shortfall_keeps_schedule_state_contract(n, k, seed):
    """Even when the chain strands fewer than K clients up, cohorts stay
    exact/distinct and the boundary-roundtrip property holds — the
    shortfall branch draws through the same rng in round order."""
    k = min(k, n)
    sampler = sampler_matrix(n, k)["markov"]
    sampler.p_on, sampler.p_off = 0.05, 0.95      # starve availability
    rng = np.random.RandomState(seed)
    for t in range(ROUNDS):
        cohort = np.asarray(sampler.sample(rng, t))
        assert len(np.unique(cohort)) == k
        assert cohort.min() >= 0 and cohort.max() < n


def test_weighted_config_echo_is_scale_free_and_digested():
    """WeightedSampler's config echo is a digest + length, not the raw
    probability vector (regression: an O(num_clients) float list in the
    JSON sidecar, string-compared every resume) — and the legacy "p"
    spelling normalizes to the same digest so old checkpoints still
    compare equal."""
    from repro.core.samplers import (WeightedSampler,
                                     normalize_sampler_config)
    w = np.arange(1, 201, dtype=np.float64)
    s = WeightedSampler(w, 5)
    cfg = s.config_dict()
    assert "p" not in cfg
    assert cfg["p_len"] == 200 and isinstance(cfg["p_digest"], str)
    # the echo stays O(1) in num_clients
    import json
    assert len(json.dumps(cfg)) < 200
    # legacy sidecar (raw vector) normalizes to the live digest form
    legacy = {k: v for k, v in cfg.items()
              if k not in ("p_digest", "p_len")}
    legacy["p"] = (w / w.sum()).tolist()
    assert normalize_sampler_config(legacy) == cfg
    # round-tripping through JSON (float value-exactness) is stable
    via_json = dict(legacy, p=json.loads(json.dumps(legacy["p"])))
    assert normalize_sampler_config(via_json) == cfg
    # different weights => different digest
    assert WeightedSampler(w[::-1], 5).config_dict()["p_digest"] \
        != cfg["p_digest"]


@given(st.integers(2, 40), st.integers(1, 6), st.integers(0, 2 ** 16))
def test_markov_state_dict_json_roundtrip(n, k, seed):
    """The Markov availability vector survives the JSON sidecar channel
    (checkpoint aux.json): dict -> json -> dict -> load_state_dict."""
    import json
    k = min(k, n)
    sampler, rng, _ = _schedule("markov", n, k, seed, rounds=3)
    state = json.loads(json.dumps(sampler.state_dict()))
    sampler_b = sampler_matrix(n, k)["markov"]
    sampler_b.load_state_dict(state)
    assert (sampler_b._avail == sampler._avail).all()
