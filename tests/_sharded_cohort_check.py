"""Subprocess body for the sharded-cohort equivalence test.

Runs on 8 FORCED host devices (the XLA flag must be set before jax
initializes, which is why this lives in its own process rather than in
the main pytest interpreter): the client-axis-sharded cohort round must
reproduce the single-device vectorized round bit-for-tolerance for every
algorithm the sharded path supports — including UNEVEN cohorts, which
pad to the next axis multiple with masked dummy clients — and the
mesh-unified ``make_fl_round_step`` must match its raw (unsharded)
counterpart.  A weighted sampler + streaming data source also run
end-to-end through the sharded, prefetched round (the ISSUE 3
acceptance path).

Invoked by tests/test_cohort.py::test_sharded_round_matches_single_device.
"""
import os

os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count=8"
                           ).strip()

import numpy as np          # noqa: E402
import jax                  # noqa: E402
import jax.numpy as jnp     # noqa: E402

from repro.core.api import (AlgoConfig, ExecConfig,         # noqa: E402
                            FederatedTrainer)
from repro.core.round import make_fl_round_step             # noqa: E402
from repro.core.samplers import WeightedSampler             # noqa: E402
from repro.launch.mesh import make_cohort_mesh              # noqa: E402
from _tree_assert import assert_trees_close                 # noqa: E402

NUM_CLIENTS = 16
K = 8                       # divisible by the 8-device client axis


def loss_fn(p, batch):
    pred = batch["x"] @ p["w"] + p["b"]
    return jnp.mean((pred - batch["y"]) ** 2)


def make_params(seed=0):
    r = np.random.RandomState(seed)
    return {"w": jnp.asarray(r.randn(4, 3), jnp.float32),
            "b": jnp.asarray(r.randn(3), jnp.float32)}


def ragged_batch_fn(c, t):
    r = np.random.RandomState(1000 * c + t)
    return [{"x": r.randn(8, 4).astype(np.float32),
             "y": r.randn(8, 3).astype(np.float32)}
            for _ in range((c % 3) + 1)]


def check_trainer(algo: str, k: int = K):
    runs = {}
    for shard in (False, True):
        cfg = ExecConfig(rounds=3, clients_per_round=k,
                         seed=7, eval_every=10 ** 9, shard_clients=shard)
        with FederatedTrainer(loss_fn, make_params(), NUM_CLIENTS,
                              ragged_batch_fn, cfg,
                              algo=AlgoConfig(name=algo, eta_l=0.05,
                                              eta_g=0.1)) as tr:
            tr.run()
        runs[shard] = tr
    assert runs[True].mesh is not None, "sharded run fell back to 1 device"
    if k % 8:
        assert runs[True]._pad_to == -(-k // 8) * 8, runs[True]._pad_to
    assert_trees_close(runs[True].params, runs[False].params)
    assert_trees_close(runs[True].server_state, runs[False].server_state)
    for rv, rs in zip(runs[True].history, runs[False].history):
        assert np.isclose(rv.train_loss, rs.train_loss, rtol=1e-4, atol=1e-6)
        for key in rv.diagnostics:
            assert np.isclose(rv.diagnostics[key], rs.diagnostics[key],
                              rtol=1e-3, atol=1e-4), (algo, key)
    print(f"[sharded==single] {algo} K={k} OK")


def check_sampler_and_streaming_source():
    """Non-uniform sampler + streaming source end-to-end through the
    sharded, prefetched, PADDED cohort round (K=6 on the 8-device axis)."""
    from repro.ingest import (StreamingImageSource,
                              build_federated_image_data)
    from repro.models.vision import VisionConfig, init_vision, vision_loss_fn
    import functools

    vc = VisionConfig(name="smoke", family="lenet5", num_classes=4,
                      image_size=16)
    data = build_federated_image_data(
        num_classes=4, num_clients=NUM_CLIENTS, alpha=0.3,
        samples_per_class=30, test_per_class=5, seed=0, image_size=16)
    source = StreamingImageSource(data, batch_size=16)
    sampler = WeightedSampler(source.client_weights(), cohort_size=6)
    params = init_vision(vc, jax.random.PRNGKey(0))
    loss = functools.partial(vision_loss_fn, vc)
    cfg = ExecConfig(rounds=3, clients_per_round=6, seed=1,
                     eval_every=10 ** 9, shard_clients=True, prefetch=True)
    with FederatedTrainer(loss, params, NUM_CLIENTS, source, cfg,
                          algo=AlgoConfig(eta_l=0.05, eta_g=0.05),
                          sampler=sampler) as tr:
        hist = tr.run()
    assert len(hist) == 3
    assert all(np.isfinite(r.train_loss) for r in hist)
    assert tr._pad_to == 8 and tr.mesh is not None
    sizes = np.asarray([len(ix) for ix in data.client_indices])
    for cohort in tr.schedule[:3]:
        assert (sizes[cohort] > 0).all()    # zero-size clients never drawn
    print("[sharded] weighted sampler + streaming source OK")


def check_fl_round_step():
    """Mesh-unified make_fl_round_step == the raw (externally-jitted) one."""
    params = make_params()
    delta0 = jax.tree.map(lambda x: jnp.zeros_like(x, jnp.float32), params)
    r = np.random.RandomState(3)
    batches = {"x": jnp.asarray(r.randn(K, 2, 8, 4), jnp.float32),
               "y": jnp.asarray(r.randn(K, 2, 8, 3), jnp.float32)}
    plain = make_fl_round_step(loss_fn, 0.05, 0.1, algorithm="feddpc")
    sharded = make_fl_round_step(loss_fn, 0.05, 0.1, algorithm="feddpc",
                                 mesh=make_cohort_mesh())
    p0, d0, m0 = jax.jit(plain)(params, delta0, batches)
    p1, d1, m1 = sharded(params, delta0, batches)
    assert_trees_close(p0, p1)
    assert_trees_close(d0, d1)
    assert np.isclose(float(m0["train_loss"]), float(m1["train_loss"]),
                      rtol=1e-5)
    print("[sharded==single] make_fl_round_step OK")


def main():
    assert len(jax.devices()) == 8, jax.devices()
    for algo in ("feddpc", "fedavg", "fedexp"):
        check_trainer(algo)
    # uneven cohorts: K=6 pads to 8 with masked dummy clients (the old
    # path warned and fell back to a single device here)
    for algo in ("feddpc", "fedvarp"):
        check_trainer(algo, k=6)
    check_fl_round_step()
    check_sampler_and_streaming_source()
    print("ALL OK")


if __name__ == "__main__":
    main()
