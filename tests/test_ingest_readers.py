"""Disk-backed dataset readers + sources (repro.ingest.readers /
.datasets) against the COMMITTED on-disk fixtures (tests/fixtures/data —
regenerate with tests/fixtures/generate_fixtures.py): format parsing,
label<->pixel association, lazy decode, the decode/augment stage's
determinism, and a CIFAR10Source end-to-end trainer round. No network,
no PIL needed (fixture images are .npy — the dependency-free format the
readers accept alongside JPEG/PNG)."""
import os

import numpy as np
import pytest

from repro.ingest import (CIFAR10Source, CIFAR100Source, TinyImageNetSource,
                          augment_images, decode_images)
from repro.ingest.readers import (load_cifar10, load_cifar100,
                                  load_tiny_imagenet, decode_image_file,
                                  write_cifar10_fixture,
                                  write_tiny_imagenet_fixture)

FIXTURES = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                        "fixtures", "data")


def _class_means_separate(images, labels):
    """The fixture writer pins per-class pixel means at
    ~(label % 10) * 23 + 25; proving the means land there shows the
    label<->pixel association survived the format round-trip (catches
    e.g. transposed planes or misaligned rows)."""
    for c in np.unique(labels):
        want = (c % 10) * 23.0 + 25.0
        if abs(images[labels == c].mean() - want) > 8.0:
            return False
    return True


# ---------------- CIFAR pickles ----------------

def test_cifar10_fixture_loads():
    d = load_cifar10(FIXTURES)
    assert d.train_images.shape == (40, 32, 32, 3)
    assert d.train_images.dtype == np.uint8
    assert d.test_images.shape == (20, 32, 32, 3)
    assert d.num_classes == 10
    assert sorted(np.unique(d.train_labels)) == list(range(10))
    assert _class_means_separate(d.test_images, d.test_labels)


def test_cifar10_multi_batch_concat():
    """data_batch_* files concatenate in sorted order (the fixture
    splits its 40 train images over two batch files)."""
    root = os.path.join(FIXTURES, "cifar-10-batches-py")
    import glob
    assert len(glob.glob(os.path.join(root, "data_batch_*"))) == 2
    d = load_cifar10(root)          # the batches dir itself also resolves
    assert len(d.train_labels) == 40


def test_cifar100_fixture_loads_fine_labels():
    d = load_cifar100(FIXTURES)
    assert d.train_images.shape == (40, 32, 32, 3)
    assert d.num_classes == 20
    assert (np.bincount(d.train_labels, minlength=20) == 2).all()


def test_missing_dataset_raises():
    with pytest.raises(FileNotFoundError):
        load_cifar10("/nonexistent/path")
    with pytest.raises(FileNotFoundError):
        load_cifar100(os.path.join(FIXTURES, "cifar-10-batches-py"))


def test_writer_reader_roundtrip(tmp_path):
    """The fixture writers ARE the format documentation: what they emit,
    the readers must parse back bit-exactly."""
    write_cifar10_fixture(str(tmp_path), per_class=2, test_per_class=1,
                          train_batches=1, seed=3)
    d = load_cifar10(str(tmp_path))
    assert d.train_images.shape == (20, 32, 32, 3)
    assert sorted(np.unique(d.test_labels)) == list(range(10))


# ---------------- TinyImageNet tree ----------------

def test_tiny_imagenet_index_and_lazy_decode():
    idx = load_tiny_imagenet(FIXTURES)
    assert idx.num_classes == 4
    assert len(idx.train_paths) == 16
    assert (np.bincount(idx.train_labels, minlength=4) == 4).all()
    assert len(idx.val_paths) == 4
    img = decode_image_file(idx.train_paths[0], image_size=64)
    assert img.shape == (64, 64, 3) and img.dtype == np.uint8
    with pytest.raises(ValueError, match="expected 32x32"):
        decode_image_file(idx.train_paths[0], image_size=32)


def test_tiny_imagenet_val_annotations(tmp_path):
    write_tiny_imagenet_fixture(str(tmp_path), num_wnids=3, per_wnid=2,
                                val_per_wnid=2, seed=9)
    idx = load_tiny_imagenet(str(tmp_path))
    assert len(idx.val_paths) == 6
    assert sorted(np.unique(idx.val_labels)) == [0, 1, 2]


# ---------------- decode / augment stage ----------------

def test_decode_range_and_dtype():
    raw = np.asarray([[[[0, 127, 255]]]], np.uint8)
    out = decode_images(raw)
    assert out.dtype == np.float32
    np.testing.assert_allclose(out.ravel(), [-1.0, -0.0039216, 1.0],
                               atol=1e-4)


def test_augment_deterministic_and_shape_preserving():
    rng = np.random.RandomState(0)
    imgs = decode_images(np.random.RandomState(1).randint(
        0, 255, size=(6, 16, 16, 3)).astype(np.uint8))
    a = augment_images(imgs, np.random.RandomState(42))
    b = augment_images(imgs, np.random.RandomState(42))
    c = augment_images(imgs, np.random.RandomState(43))
    assert a.shape == imgs.shape
    np.testing.assert_array_equal(a, b)         # same rng -> same bytes
    assert not np.array_equal(a, c)
    del rng


# ---------------- DataSource impls ----------------

def test_cifar10_source_batches_deterministic():
    src = CIFAR10Source(FIXTURES, num_clients=4, alpha=0.5, batch_size=8,
                        augment=True, seed=0)
    assert src.num_classes == 10
    assert src.client_weights().sum() == 40
    a = [b for b in src.client_batches(1, 3)]
    b = [b for b in src.client_batches(1, 3)]
    assert len(a) >= 1
    for x, y in zip(a, b):      # pure function of (client, round)
        np.testing.assert_array_equal(x["images"], y["images"])
        np.testing.assert_array_equal(x["labels"], y["labels"])
    c = [b for b in src.client_batches(1, 4)]
    assert not np.array_equal(a[0]["images"], c[0]["images"])  # reshuffles
    for batch in a:
        assert batch["images"].shape == (8, 32, 32, 3)
        assert batch["images"].dtype == np.float32
        assert batch["labels"].dtype == np.int32


def test_wrap_pad_small_clients():
    """A client whose shard is smaller than the batch size still yields
    one full batch (wrap-around padding, matching ingest/images)."""
    src = CIFAR100Source(FIXTURES, num_clients=10, alpha=0.3, batch_size=16,
                         seed=0, min_size=1)
    smallest = int(np.argmin([len(ix) for ix in src.client_indices]))
    batches = list(src.client_batches(smallest, 0))
    assert len(batches) >= 1
    assert batches[0]["images"].shape[0] == 16


def test_tiny_imagenet_source_end_to_end():
    src = TinyImageNetSource(FIXTURES, num_clients=3, alpha=1.0,
                             batch_size=4, seed=0, min_size=1)
    assert src.num_classes == 4
    batch = next(iter(src.client_batches(0, 0)))
    assert batch["images"].shape == (4, 64, 64, 3)
    te_x, te_y = src.test_arrays()
    assert te_x.shape == (4, 64, 64, 3) and te_x.dtype == np.float32
    assert te_y.shape == (4,)


def test_cifar10_source_trains_a_round():
    """The disk-backed source plugs into the trainer through the same §3
    protocol as every other source — prefetched, device-staged."""
    import functools
    import jax
    from repro.core.api import AlgoConfig, ExecConfig, FederatedTrainer
    from repro.models.vision import (VisionConfig, init_vision,
                                     vision_accuracy, vision_loss_fn)
    src = CIFAR10Source(FIXTURES, num_clients=4, alpha=1.0, batch_size=8,
                        seed=0, min_size=2)
    vc = VisionConfig(name="cifar-smoke", family="lenet5", num_classes=10)
    params = init_vision(vc, jax.random.PRNGKey(0))
    te_x, te_y = src.test_arrays()
    import jax.numpy as jnp
    te_x, te_y = jnp.asarray(te_x), jnp.asarray(te_y)
    eval_fn = jax.jit(lambda p: vision_accuracy(vc, p, te_x, te_y))
    with FederatedTrainer(
            functools.partial(vision_loss_fn, vc), params, 4, src,
            ExecConfig(rounds=2, clients_per_round=2, eval_every=1,
                       prefetch_depth=4),
            eval_fn, algo=AlgoConfig(eta_l=0.02, eta_g=0.02)) as tr:
        hist = tr.run()
    assert np.isfinite(hist[-1].train_loss)
    assert hist[-1].test_accuracy is not None
